"""Model assembly: block dispatch, layer-stack scan, train/prefill/decode.

The stack compiles as ``prefix (unrolled) + lax.scan over super-blocks +
tail (unrolled)`` with per-super-block rematerialization, so a 96-layer
340B model lowers to a compact HLO whose memory profile is
(1 super-block of activations) x (scan carry), not 96 layers of residuals.

Three entry points per architecture (what the dry-run lowers per shape):
  ``loss``         — training forward (train_* shapes)
  ``prefill``      — full-sequence forward that also builds the decode cache
                     (prefill_* shapes)
  ``decode_step``  — one new token against the cache (decode_* / long_*)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, constrain_residual
from repro.models import attention as attn
from repro.models import recurrent as rec
from repro.models.config import ModelConfig
from repro.models.layers import chunked_ce_loss, embed_tokens, mlp_apply, rms_norm
from repro.models.moe import moe_apply

__all__ = ["LM"]


# --------------------------------------------------------------------------- #
# single-block apply                                                           #
# --------------------------------------------------------------------------- #


def _block_full(cfg: ModelConfig, kind: str, p: dict, x: jax.Array, *,
                pos0: int, dense: bool, enc_out: jax.Array | None,
                causal: bool, build_cache: bool):
    """Returns (x, aux_loss, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    x = constrain_residual(x)
    if kind == "attn":
        h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
        window = cfg.local_window
        if cfg.mla is not None:
            if build_cache:
                y, lat = attn.mla_full(cfg, p["attn"], h_in, pos0=pos0,
                                       return_cache=True)
                cache = {"latent": lat}
            else:
                y = attn.mla_full(cfg, p["attn"], h_in, pos0=pos0)
        else:
            if build_cache:
                y, (k, v) = attn.gqa_full(cfg, p["attn"], h_in, pos0=pos0,
                                          window=window, causal=causal,
                                          return_cache=True)
                cache = {"k": k, "v": v}
            else:
                y = attn.gqa_full(cfg, p["attn"], h_in, pos0=pos0,
                                  window=window, causal=causal)
        x = x + y
        if enc_out is not None and "xattn" in p:
            xh = rms_norm(x, p["ln_x"], cfg.norm_eps)
            x = x + attn.gqa_full(cfg, p["xattn"], xh, cross_kv=enc_out,
                                  causal=False, use_rope=False)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None and not dense:
            y2, aux = moe_apply(cfg, p["mlp"], h2)
        else:
            y2 = mlp_apply(cfg, p["mlp"], h2)
        return x + y2, aux, cache

    if kind == "rglru":
        h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
        if build_cache:
            y, st = rec.rglru_full(cfg, p["rglru"], h_in, return_state=True)
            cache = st
        else:
            y = rec.rglru_full(cfg, p["rglru"], h_in)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp_apply(cfg, p["mlp"], h2), aux, cache

    if kind == "mlstm":
        h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
        if build_cache:
            y, st = rec.mlstm_full(cfg, p["mlstm"], h_in, return_state=True)
            cache = st
        else:
            y = rec.mlstm_full(cfg, p["mlstm"], h_in)
        return x + y, aux, cache

    if kind == "slstm":
        h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
        if build_cache:
            y, st = rec.slstm_full(cfg, p["slstm"], h_in, return_state=True)
            cache = st
        else:
            y = rec.slstm_full(cfg, p["slstm"], h_in)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + rec.slstm_ffn(p["slstm"], h2), aux, cache

    raise ValueError(f"unknown block kind {kind!r}")


def _block_decode(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                  cache: dict, pos: jax.Array, *, dense: bool,
                  enc_out: jax.Array | None):
    """One-token step.  Returns (x, new_cache)."""
    if kind == "attn":
        h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
        if cfg.mla is not None:
            y, cache = attn.mla_decode(cfg, p["attn"], h_in, cache, pos)
        else:
            y, cache = attn.gqa_decode(cfg, p["attn"], h_in, cache, pos,
                                       window=cfg.local_window)
        x = x + y
        if enc_out is not None and "xattn" in p:
            xh = rms_norm(x, p["ln_x"], cfg.norm_eps)
            x = x + attn.gqa_decode_cross(cfg, p["xattn"], xh, enc_out)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe is not None and not dense:
            y2, _ = moe_apply(cfg, p["mlp"], h2)
        else:
            y2 = mlp_apply(cfg, p["mlp"], h2)
        return x + y2, cache

    if kind == "rglru":
        h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, cache = rec.rglru_decode(cfg, p["rglru"], h_in, cache)
        x = x + y
        return x + mlp_apply(cfg, p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps)), cache

    if kind == "mlstm":
        h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, cache = rec.mlstm_decode(cfg, p["mlstm"], h_in, cache)
        return x + y, cache

    if kind == "slstm":
        h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, cache = rec.slstm_decode(cfg, p["slstm"], h_in, cache)
        x = x + y
        return x + rec.slstm_ffn(p["slstm"], rms_norm(x, p["ln2"], cfg.norm_eps)), cache

    raise ValueError(kind)


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind == "attn":
        if cfg.mla is not None:
            return attn.init_mla_cache(cfg, batch, max_len)
        return attn.init_gqa_cache(cfg, batch, max_len, cfg.local_window)
    if kind == "rglru":
        return rec.init_rglru_state(cfg, batch)
    if kind == "mlstm":
        return rec.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return rec.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def _cache_from_prefill(cfg: ModelConfig, kind: str, built: dict | None,
                        batch: int, seq: int, max_len: int):
    """Convert prefill-built per-layer state into a decode cache of max_len."""
    if built is None:
        return _init_block_cache(cfg, kind, batch, max_len)
    if kind == "attn" and cfg.mla is not None:
        cache = attn.init_mla_cache(cfg, batch, max_len)
        lat = jax.lax.dynamic_update_slice(
            cache["latent"], built["latent"].astype(cache["latent"].dtype),
            (0, 0, 0))
        return {"latent": lat}
    if kind == "attn":
        cache = attn.init_gqa_cache(cfg, batch, max_len, cfg.local_window)
        size = cache["k"].shape[2]
        k, v = built["k"].astype(cache["k"].dtype), built["v"].astype(cache["v"].dtype)
        if cfg.local_window > 0 and seq > size:
            # keep the last `size` positions, ring-aligned: slot = pos % size
            positions = jnp.arange(seq - size, seq)
            slots = positions % size
            ck = cache["k"].at[:, :, slots, :].set(k[:, :, -size:, :])
            cv = cache["v"].at[:, :, slots, :].set(v[:, :, -size:, :])
            sp = cache["slot_pos"].at[:, slots].set(
                positions.astype(jnp.int32)[None, :])
            return {"k": ck, "v": cv, "slot_pos": sp}
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        sp = cache["slot_pos"].at[:, :seq].set(
            jnp.arange(seq, dtype=jnp.int32)[None, :])
        return {"k": ck, "v": cv, "slot_pos": sp}
    return built  # recurrent states carry over unchanged


# --------------------------------------------------------------------------- #
# whole model                                                                  #
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # ----- input embedding / frontends ---------------------------------------
    def _inputs(self, params: dict, batch: dict):
        """Returns (x, labels|None, enc_out|None)."""
        cfg = self.cfg
        labels = batch.get("labels")
        enc_out = None
        if cfg.is_encdec:
            frames = batch["frames"].astype(cfg.activation_dtype)
            frames = frames @ params["frontend"]["adapter"].astype(frames.dtype)
            enc_out = self._encode(params, frames)
        x = embed_tokens(cfg, params["embed"], batch["tokens"])
        if cfg.frontend == "vision":
            patches = batch["patches"].astype(cfg.activation_dtype)
            patches = patches @ params["frontend"]["adapter"].astype(patches.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            if labels is not None:
                pad = jnp.full(patches.shape[:2], -1, labels.dtype)
                labels = jnp.concatenate([pad, labels], axis=1)
        x = constrain(x, ("pod", "data"), None, None)
        return x, labels, enc_out

    def _encode(self, params: dict, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        enc = params["encoder"]

        def body(x, lp):
            x, _, _ = _block_full(cfg, "attn", lp["0_attn"], x, pos0=0,
                                  dense=True, enc_out=None, causal=False,
                                  build_cache=False)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(body), frames, enc["stack"])
        return rms_norm(x, enc["final_norm"], cfg.norm_eps)

    # ----- layer-stack traversal ----------------------------------------------
    def _super_full(self, sp: dict, x: jax.Array, *, pos0: int,
                    enc_out, build_cache: bool):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        caches = {}
        for key in sorted(sp.keys(), key=lambda s: int(s.split("_")[0])):
            kind = key.split("_", 1)[1]
            x, a, c = _block_full(cfg, kind, sp[key], x, pos0=pos0, dense=False,
                                  enc_out=enc_out, causal=True,
                                  build_cache=build_cache)
            aux = aux + a
            if build_cache:
                caches[key] = c
        return x, aux, caches

    def _forward(self, params: dict, x: jax.Array, *, enc_out=None,
                 build_cache: bool = False, remat: bool = True):
        """Shared full-sequence traversal.  Returns (x, aux, caches)."""
        cfg = self.cfg
        plan = cfg.layer_plan()
        aux_total = jnp.zeros((), jnp.float32)
        caches: dict[str, Any] = {}

        for section, dense in (("prefix", True), ):
            if section in params:
                caches[section] = {}
                for key in sorted(params[section],
                                  key=lambda s: int(s.split("_")[0])):
                    kind = key.split("_", 1)[1]
                    x, a, c = _block_full(cfg, kind, params[section][key], x,
                                          pos0=0, dense=dense, enc_out=enc_out,
                                          causal=True, build_cache=build_cache)
                    aux_total = aux_total + a
                    if build_cache:
                        caches[section][key] = c

        if "stack" in params:
            def body(carry, lp):
                xx, aux = carry
                xx, a, c = self._super_full(lp, xx, pos0=0, enc_out=enc_out,
                                            build_cache=build_cache)
                return (xx, aux + a), c

            # remat policy (REPRO_REMAT_POLICY): 'full' recomputes everything
            # in backward (min residency, max recompute); 'dots' saves matmul
            # outputs (the §Perf compute<->memory trade lever).
            import os as _os
            if _os.environ.get("REPRO_REMAT_POLICY", "full") == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                body_fn = jax.checkpoint(body, policy=policy) if remat else body
            else:
                body_fn = jax.checkpoint(body) if remat else body

            # 2-level (recursive) checkpointing: REPRO_REMAT_GROUP=g saves
            # only every g-th residual during the forward scan (n/g group
            # boundaries + g per-layer saves inside the one group being
            # differentiated) — O(n/g + g) residency instead of O(n).
            # The enabler for 96-layer d=18432 training at 16 GB/chip.
            g = int(_os.environ.get("REPRO_REMAT_GROUP", "1"))
            n_super = jax.tree_util.tree_leaves(params["stack"])[0].shape[0]
            if remat and not build_cache and g > 1 and n_super % g == 0:
                grouped = jax.tree_util.tree_map(
                    lambda a: a.reshape((n_super // g, g) + a.shape[1:]),
                    params["stack"])

                def group_body(carry, glp):
                    cc, _ = jax.lax.scan(body_fn, carry, glp)
                    return cc, None

                (x, aux_total), _ = jax.lax.scan(
                    jax.checkpoint(group_body), (x, aux_total), grouped)
                stack_caches = None
            else:
                (x, aux_total), stack_caches = jax.lax.scan(
                    body_fn, (x, aux_total), params["stack"])
            if build_cache:
                caches["stack"] = stack_caches

        if "tail" in params:
            caches["tail"] = {}
            for key in sorted(params["tail"], key=lambda s: int(s.split("_")[0])):
                kind = key.split("_", 1)[1]
                x, a, c = _block_full(cfg, kind, params["tail"][key], x,
                                      pos0=0, dense=False, enc_out=enc_out,
                                      causal=True, build_cache=build_cache)
                aux_total = aux_total + a
                if build_cache:
                    caches["tail"][key] = c

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux_total, caches

    # ----- public entry points ---------------------------------------------------
    def loss(self, params: dict, batch: dict, *, remat: bool = True):
        cfg = self.cfg
        x, labels, enc_out = self._inputs(params, batch)
        x, aux, _ = self._forward(params, x, enc_out=enc_out, remat=remat)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        ce, metrics = chunked_ce_loss(cfg, head, x, labels)
        metrics["aux_loss"] = aux
        return ce + aux, metrics

    def prefill(self, params: dict, batch: dict, *, max_len: int,
                remat: bool = True):
        """Forward + cache build.  Returns (cache, last-position logits)."""
        cfg = self.cfg
        x, _, enc_out = self._inputs(params, batch)
        b, s, _ = x.shape
        x, _, built = self._forward(params, x, enc_out=enc_out,
                                    build_cache=True, remat=remat)
        cache = self._caches_to_decode(built, b, s, max_len)
        cache["pos"] = jnp.full((b,), s, jnp.int32)  # per-lane positions
        if enc_out is not None:
            cache["enc_out"] = enc_out
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = (x[:, -1, :] @ head.astype(x.dtype).T).astype(jnp.float32)
        return cache, logits[:, : cfg.vocab_size]

    def _caches_to_decode(self, built: dict, b: int, s: int, max_len: int):
        cfg = self.cfg
        out: dict[str, Any] = {}
        for section in ("prefix", "tail"):
            if section in built:
                out[section] = {
                    key: _cache_from_prefill(cfg, key.split("_", 1)[1],
                                             built[section][key], b, s, max_len)
                    for key in built[section]}
        if "stack" in built:
            # vmap the conversion over the scan (leading) axis of every leaf
            def per_layer(subtree, key):
                kind = key.split("_", 1)[1]
                return jax.vmap(lambda bt: _cache_from_prefill(
                    cfg, kind, bt, b, s, max_len))(subtree)
            out["stack"] = {k: per_layer(built["stack"][k], k)
                            for k in built["stack"]}
        return out

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        plan = cfg.layer_plan()
        out: dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
        if plan.prefix:
            out["prefix"] = {f"{i}_{k}": _init_block_cache(cfg, k, batch, max_len)
                             for i, k in enumerate(plan.prefix)}
        if plan.n_super:
            def one(kind):
                return _init_block_cache(cfg, kind, batch, max_len)
            stack = {f"{i}_{k}": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (plan.n_super,) + a.shape),
                one(k)) for i, k in enumerate(plan.super_block)}
            out["stack"] = stack
        if plan.tail:
            out["tail"] = {f"{i}_{k}": _init_block_cache(cfg, k, batch, max_len)
                           for i, k in enumerate(plan.tail)}
        return out

    def decode_step(self, params: dict, cache: dict, tokens: jax.Array):
        """tokens: (B, 1) int32.  Returns (logits (B, V), new cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        enc_out = cache.get("enc_out")
        x = embed_tokens(cfg, params["embed"], tokens)
        new_cache: dict[str, Any] = {"pos": pos + 1}
        if enc_out is not None:
            new_cache["enc_out"] = enc_out

        for section in ("prefix",):
            if section in params:
                new_cache[section] = {}
                for key in sorted(params[section],
                                  key=lambda s: int(s.split("_")[0])):
                    kind = key.split("_", 1)[1]
                    x, c = _block_decode(cfg, kind, params[section][key], x,
                                         cache[section][key], pos, dense=True,
                                         enc_out=enc_out)
                    new_cache[section][key] = c

        if "stack" in params:
            def body(xx, inp):
                lp, lc = inp
                ncs = {}
                for key in sorted(lp.keys(), key=lambda s: int(s.split("_")[0])):
                    kind = key.split("_", 1)[1]
                    xx, nc = _block_decode(cfg, kind, lp[key], xx, lc[key], pos,
                                           dense=False, enc_out=enc_out)
                    ncs[key] = nc
                return xx, ncs

            x, stack_cache = jax.lax.scan(body, x,
                                          (params["stack"], cache["stack"]))
            new_cache["stack"] = stack_cache

        if "tail" in params:
            new_cache["tail"] = {}
            for key in sorted(params["tail"], key=lambda s: int(s.split("_")[0])):
                kind = key.split("_", 1)[1]
                x, c = _block_decode(cfg, kind, params["tail"][key], x,
                                     cache["tail"][key], pos, dense=False,
                                     enc_out=enc_out)
                new_cache["tail"][key] = c

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = (x[:, 0, :] @ head.astype(x.dtype).T).astype(jnp.float32)
        return logits[:, : cfg.vocab_size], new_cache
