"""Shared neural-net layers (pure functions over param pytrees)."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["rms_norm", "rope", "mlp_apply", "causal_conv1d", "chunked_ce_loss",
           "embed_tokens"]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, *, theta: float = 10_000.0,
         pct: float = 1.0) -> jax.Array:
    """Rotary embedding on the last dim. x: (..., S, H, hd); positions: (S,) or (B, S).

    ``pct`` < 1 rotates only the first ``pct * hd`` dims (StableLM-2 partial
    rotary).  Pairing is (even, odd) interleaved halves: (x1, x2) rotation on
    split halves of the rotary slice.
    """
    hd = x.shape[-1]
    rot = int(hd * pct)
    rot -= rot % 2
    if rot == 0:
        return x
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (S, half)
        ang = ang[None, :, None, :]                                    # (1,S,1,half)
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs         # (B,S,half)
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:rot].astype(jnp.float32)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)


def mlp_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Dense MLP: swiglu | geglu | relu2 (Nemotron squared-ReLU)."""
    up = x @ p["w_in"].astype(x.dtype)
    if cfg.mlp_kind == "relu2":
        h = jnp.square(jax.nn.relu(up))
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(up) * (x @ p["w_gate"].astype(x.dtype))
    else:  # swiglu
        h = jax.nn.silu(up) * (x @ p["w_gate"].astype(x.dtype))
    return h @ p["w_out"].astype(x.dtype)


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal temporal conv. x: (B,S,C); w: (K,C); b: (C,).

    Implemented as a sum of K shifted elementwise products (no conv op:
    stays TP-shardable on C with zero collectives).  ``state`` is the last
    K-1 inputs from the previous segment, (B, K-1, C); returns (out, new
    state) so prefill hands decode a warm buffer.
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, C)
    s = x.shape[1]
    out = b.astype(x.dtype)
    for j in range(k):
        out = out + xp[:, j:j + s, :] * w[j].astype(x.dtype)
    return out, xp[:, -(k - 1):, :] if k > 1 else jnp.zeros(
        (x.shape[0], 0, x.shape[2]), x.dtype)


def embed_tokens(cfg: ModelConfig, embed: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(embed, tokens, axis=0).astype(cfg.activation_dtype)


def chunked_ce_loss(cfg: ModelConfig, head: jax.Array, x: jax.Array,
                    labels: jax.Array) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Cross-entropy with the vocab projection computed in sequence chunks.

    Never materializes the full (B, S, V) logits tensor: peak activation is
    (B, S/chunks, V_padded) — the single biggest memory-term lever for the
    256k-vocab archs.  Padded vocab columns are masked with -1e30.
    labels == -1 means "ignore position".
    """
    b, s, d = x.shape
    chunks = cfg.logit_chunks if s % cfg.logit_chunks == 0 else 1
    sc = s // chunks
    vp, v = cfg.padded_vocab, cfg.vocab_size
    hw = head.astype(cfg.activation_dtype)

    def body(carry, inp):
        tot, cnt = carry
        xc, lc = inp                              # (B, sc, D), (B, sc)
        logits = (xc @ hw.T).astype(jnp.float32)  # (B, sc, Vp)
        if vp != v:
            col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            logits = jnp.where(col < v, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        safe = jnp.maximum(lc, 0)
        lbl = jnp.sum(jnp.where(col == safe[..., None], logits, 0.0), axis=-1)
        valid = (lc >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - lbl) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    xs = (x.reshape(b, chunks, sc, d).swapaxes(0, 1),
          labels.reshape(b, chunks, sc).swapaxes(0, 1))
    # checkpoint: backward recomputes each chunk's logits instead of saving
    # chunks x (B, sc, Vp) fp32 — the whole point of chunking the loss.
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros(()), jnp.zeros(())), xs)
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss, {"ce_sum": tot, "n_tokens": cnt}
