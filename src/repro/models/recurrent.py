"""Recurrent block families: RG-LRU (RecurrentGemma/Griffin) and xLSTM
(mLSTM matrix memory, sLSTM scalar memory).

Full-sequence paths:
  * RG-LRU uses ``jax.lax.associative_scan`` — the recurrence
    h_t = a_t h_{t-1} + b_t is linear, so training parallelizes to
    log-depth on TPU instead of an O(S) sequential chain.
  * mLSTM/sLSTM use ``lax.scan`` over time (their gate stabilization is
    not associative); states are O(d^2/head) and O(d) respectively, which
    is what makes the 500k-token decode shape tractable for this family.

Every function also has a single-step decode form carrying explicit state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import causal_conv1d, rms_norm

__all__ = ["rglru_full", "rglru_decode", "init_rglru_state",
           "mlstm_full", "mlstm_decode", "init_mlstm_state",
           "slstm_full", "slstm_decode", "init_slstm_state", "slstm_ffn"]

_C_RGLRU = 8.0  # Griffin's fixed recurrence sharpness constant


# --- RG-LRU ---------------------------------------------------------------------


def _rglru_gates(p: dict, u: jax.Array):
    """u: (..., W) conv output -> (log_a, beta-scaled input)."""
    r = jax.nn.sigmoid((u @ p["w_a"].astype(u.dtype)
                        + p["b_a"].astype(u.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_i"].astype(u.dtype)
                        + p["b_i"].astype(u.dtype)).astype(jnp.float32))
    log_a = -_C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    x_in = beta * (i * u.astype(jnp.float32))
    return a, x_in


def rglru_full(cfg: ModelConfig, p: dict, x: jax.Array,
               conv_state: jax.Array | None = None,
               h0: jax.Array | None = None, *, return_state: bool = False):
    """Griffin recurrent block over a full sequence. x: (B, S, D)."""
    y = jax.nn.gelu(x @ p["w_y"].astype(x.dtype))
    u, conv_out = causal_conv1d(x @ p["w_x"].astype(x.dtype),
                                p["conv_w"], p["conv_b"], conv_state)
    a, x_in = _rglru_gates(p, u)
    if h0 is not None:
        # fold the carried state into step 0: b_0 <- a_0 h0 + b_0
        x_in = x_in.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    out = ((h.astype(x.dtype) * y) @ p["w_ro"].astype(x.dtype))
    if return_state:
        return out, {"conv": conv_out, "h": h[:, -1, :].astype(x.dtype)}
    return out


def init_rglru_state(cfg: ModelConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    dt = cfg.activation_dtype
    return {"conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dt),
            "h": jnp.zeros((batch, w), dt)}


def rglru_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    """One step. x: (B, 1, D)."""
    out, new_state = rglru_full(cfg, p, x, conv_state=state["conv"],
                                h0=state["h"], return_state=True)
    return out, new_state


# --- mLSTM (xLSTM matrix memory) ---------------------------------------------------


def _mlstm_step(state, inp):
    """state: (C (B,H,dk,dv), n (B,H,dk), m (B,H)); one time step."""
    c, n, m = state
    q, k, v, i_pre, f_pre = inp                     # (B,H,dk) x2, (B,H,dv), (B,H) x2
    log_f = -jax.nn.softplus(-f_pre)                # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c = f_g[..., None, None] * c + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_g[..., None] * n + i_g[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h = jnp.einsum("bhkv,bhk->bhv", c, q) / denom[..., None]
    return (c, n, m_new), h


def _mlstm_qkvif(cfg: ModelConfig, p: dict, u: jax.Array, v_src: jax.Array):
    b, s, di = u.shape
    h = cfg.n_heads
    dh = di // h
    q = (u @ p["w_q"].astype(u.dtype)).reshape(b, s, h, dh)
    k = (u @ p["w_k"].astype(u.dtype)).reshape(b, s, h, dh) * dh ** -0.5
    v = (v_src @ p["w_v"].astype(u.dtype)).reshape(b, s, h, dh)
    i_pre = (u @ p["w_if"].astype(u.dtype) + p["b_if"].astype(u.dtype))
    f_pre = (u @ p["w_ff"].astype(u.dtype) + p["b_ff"].astype(u.dtype))
    return (q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            i_pre.astype(jnp.float32), f_pre.astype(jnp.float32))


def _mlstm_out(cfg: ModelConfig, p: dict, h_seq: jax.Array, u: jax.Array,
               gate: jax.Array, x_dtype) -> jax.Array:
    b, s, nh, dh = h_seq.shape
    di = nh * dh
    flat = h_seq.reshape(b, s, di)
    # per-head rms normalization (GroupNorm stand-in), then skip + output gate
    flat = flat.reshape(b, s, nh, dh)
    flat = flat * jax.lax.rsqrt(jnp.mean(flat * flat, -1, keepdims=True) + 1e-6)
    flat = flat.reshape(b, s, di).astype(x_dtype)
    y = (flat + p["skip_scale"].astype(x_dtype) * u) * jax.nn.silu(gate)
    return y @ p["w_down"].astype(x_dtype)


def mlstm_full(cfg: ModelConfig, p: dict, x: jax.Array,
               state=None, *, return_state: bool = False):
    b, s, d = x.shape
    up = x @ p["w_up"].astype(x.dtype)
    gate = x @ p["w_gate_up"].astype(x.dtype)
    conv_state = state["conv"] if state is not None else None
    u, conv_out = causal_conv1d(up, p["conv_w"], p["conv_b"], conv_state)
    u = jax.nn.silu(u)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, p, u, up)
    h = cfg.n_heads
    dh = (2 * d) // h
    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]
    xs = jax.tree_util.tree_map(lambda a: a.swapaxes(0, 1), (q, k, v, i_pre, f_pre))
    (c, n, m), hs = jax.lax.scan(_mlstm_step, (c0, n0, m0), xs)
    hs = hs.swapaxes(0, 1)                          # (B,S,H,dh)
    out = _mlstm_out(cfg, p, hs, u, gate, x.dtype)
    if return_state:
        return out, {"c": c, "n": n, "m": m, "conv": conv_out}
    return out


def init_mlstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    h = cfg.n_heads
    dh = (2 * d) // h
    return {"c": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, 2 * d),
                              cfg.activation_dtype)}


def mlstm_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    return mlstm_full(cfg, p, x, state, return_state=True)


# --- sLSTM (xLSTM scalar memory) ----------------------------------------------------


def _slstm_gates(cfg: ModelConfig, p: dict, xt: jax.Array, h_prev: jax.Array):
    """xt: (B, D) input at one step; h_prev: (B, D).  Returns 4 pre-acts."""
    b, d = xt.shape
    nh = cfg.n_heads
    dh = d // nh
    hh = h_prev.reshape(b, nh, dh)
    outs = []
    for g in ("i", "f", "z", "o"):
        rec = jnp.einsum("bhk,hkj->bhj", hh, p[f"r_{g}"].astype(xt.dtype))
        outs.append(xt @ p[f"w_{g}"].astype(xt.dtype) + rec.reshape(b, d)
                    + p[f"b_{g}"].astype(xt.dtype))
    return [o.astype(jnp.float32) for o in outs]


def _slstm_step(cfg: ModelConfig, p: dict, state, xt):
    c, n, h, m = state
    i_pre, f_pre, z_pre, o_pre = _slstm_gates(cfg, p, xt, h.astype(xt.dtype))
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c = f_g * c + i_g * jnp.tanh(z_pre)
    n = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new), h_new


def slstm_ffn(p: dict, y: jax.Array) -> jax.Array:
    """Post-recurrence gated FFN (projection factor 4/3); applied by the block."""
    return (jax.nn.silu(y @ p["ffn_in"].astype(y.dtype))
            * (y @ p["ffn_gate"].astype(y.dtype))) @ p["ffn_out"].astype(y.dtype)


def slstm_full(cfg: ModelConfig, p: dict, x: jax.Array,
               state=None, *, return_state: bool = False):
    """Recurrence only — block wiring adds the residual + slstm_ffn."""
    b, s, d = x.shape
    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        st = (z, z, z, jnp.full((b, d), -1e30, jnp.float32))
    else:
        st = (state["c"], state["n"], state["h"], state["m"])
    step = lambda carry, xt: _slstm_step(cfg, p, carry, xt)
    (c, n, h, m), hs = jax.lax.scan(step, st, x.swapaxes(0, 1))
    out = hs.swapaxes(0, 1).astype(x.dtype)         # (B,S,D)
    if return_state:
        return out, {"c": c, "n": n, "h": h, "m": m}
    return out


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    return slstm_full(cfg, p, x, state, return_state=True)
